"""Codebase contract linter: the repo's hard-won invariants as AST rules.

Several load-bearing properties of this codebase were, until now,
enforced only by docstrings:

* ``R001`` — the distributed worker tier is jax-free: nothing
  module-level reachable from ``<pkg>.distributed.worker`` or
  ``<pkg>.distributed.transport`` may import ``jax`` at module level
  (workers are long-lived preprocessing processes; pulling jax into
  them costs ~1s of import, device initialization, and fork hazards).
* ``R002`` — fork-side byte-kernel paths stay module-level-jax-free:
  ``<pkg>.core.bytesops`` / ``core.executor`` / ``core.pipeline`` run
  inside forked process-pool workers, and jax is fork-unsafe (the
  pallas backend imports it lazily, post-fork-check, on purpose).
* ``R003`` — cache and heartbeat file writes are atomic: any function
  in the cache/heartbeat modules that writes a file must stage through
  a temp file and ``os.replace`` (a monitor must never read a torn
  write).
* ``R004`` — no bare ``except:`` in executor/runtime/distributed code
  (it swallows ``KeyboardInterrupt``/``SystemExit`` and turns worker
  shutdown into a hang).
* ``R005`` — the serve hot path (``runtime.serve_loop`` /
  ``runtime.row_program``) never imports the shard/shm/pool machinery:
  ``core.executor``, ``core.async_loader``, ``repro.distributed`` or
  ``multiprocessing``. A served request must stay a pure per-row
  compute path — pools, shared memory, and coordinators belong to the
  training data plane only. Package ``__init__`` re-export hubs are
  excluded from the traversal (importing ``repro.core.bytesops``
  executes ``core/__init__`` too, but that is a re-export edge, not
  machinery *use*); direct imports are what the rule polices.

Everything here is stdlib-only (``ast`` + ``pathlib``): the CLI
(``python -m repro.analysis --contracts src/repro``) runs in CI's lint
job, which installs no numpy/jax. Function-level (lazy) imports are
exempt from R001/R002 by construction — only module-level statements
(including those under top-level ``if``/``try``) execute at import time.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Iterator, Sequence

from .diagnostics import Diagnostic

ALL_RULES = ("R001", "R002", "R003", "R004", "R005")

# Module suffixes (relative to the package) whose import closure must be
# jax-free, per rule.
_WORKER_TIER_ROOTS = ("distributed.worker", "distributed.transport")
_FORK_SIDE_ROOTS = ("core.bytesops", "core.executor", "core.pipeline")

# The serve hot path (R005) and the shard/shm/pool machinery it must
# never reach. Internal names are package-relative prefixes; external
# names are top-level import bases.
_SERVE_HOT_ROOTS = ("runtime.serve_loop", "runtime.row_program")
_SERVE_BANNED_INTERNAL = ("core.executor", "core.async_loader", "distributed")
_SERVE_BANNED_EXTERNAL = ("multiprocessing",)

# Files whose writes must be atomic (cache + heartbeat surfaces), relative
# to the package root.
_ATOMIC_WRITE_SCOPE = (
    "core/executor.py",
    "runtime/fault_tolerance.py",
    "distributed/coordinator.py",
    "distributed/worker.py",
)

# Path prefixes (relative to the package root) where bare except is banned.
_BARE_EXCEPT_SCOPE = ("core/executor.py", "runtime/", "distributed/")


@dataclass
class ModuleInfo:
    """One module's import surface, module-level statements only."""

    name: str
    path: Path
    internal: list[tuple[str, int]] = field(default_factory=list)
    external: dict[str, int] = field(default_factory=dict)  # base -> lineno


def _module_level_stmts(body: Sequence[ast.stmt]) -> Iterator[ast.stmt]:
    """Statements that execute at import time: the module body plus the
    bodies of top-level ``if``/``try``/``with`` — but never function or
    class bodies (those are the sanctioned lazy-import escape hatch)."""
    for stmt in body:
        yield stmt
        if isinstance(stmt, ast.If):
            yield from _module_level_stmts(stmt.body)
            yield from _module_level_stmts(stmt.orelse)
        elif isinstance(stmt, ast.Try):
            yield from _module_level_stmts(stmt.body)
            yield from _module_level_stmts(stmt.orelse)
            yield from _module_level_stmts(stmt.finalbody)
            for handler in stmt.handlers:
                yield from _module_level_stmts(handler.body)
        elif isinstance(stmt, ast.With):
            yield from _module_level_stmts(stmt.body)


def build_import_graph(root: Path) -> dict[str, ModuleInfo]:
    """Parse every ``.py`` under the package dir ``root`` (its basename is
    the package name) into a module-level import graph. Namespace
    subpackages (no ``__init__.py``) are handled: they contribute no
    import-time code, so they simply have no node."""
    root = Path(root).resolve()
    pkg = root.name
    modules: dict[str, ModuleInfo] = {}
    for py in sorted(root.rglob("*.py")):
        rel_parts = py.relative_to(root).with_suffix("").parts
        if rel_parts[-1] == "__init__":
            rel_parts = rel_parts[:-1]
        name = ".".join((pkg,) + rel_parts)
        modules[name] = ModuleInfo(name, py)

    def record(mod: ModuleInfo, dotted: str, lineno: int) -> None:
        parts = dotted.split(".")
        if parts[0] != pkg:
            mod.external.setdefault(parts[0], lineno)
            return
        # The imported module itself (or the deepest known prefix of it)...
        for k in range(len(parts), 0, -1):
            cand = ".".join(parts[:k])
            if cand in modules:
                mod.internal.append((cand, lineno))
                break
        # ...plus every parent package with a real __init__.py: importing
        # a.b.c executes a/__init__.py and a/b/__init__.py too.
        for k in range(1, len(parts)):
            cand = ".".join(parts[:k])
            if cand in modules and modules[cand].path.name == "__init__.py":
                mod.internal.append((cand, lineno))

    for mod in modules.values():
        try:
            tree = ast.parse(mod.path.read_text(encoding="utf-8"))
        except (SyntaxError, UnicodeDecodeError, OSError):
            continue
        is_package = mod.path.name == "__init__.py"
        for stmt in _module_level_stmts(tree.body):
            if isinstance(stmt, ast.Import):
                for alias in stmt.names:
                    record(mod, alias.name, stmt.lineno)
            elif isinstance(stmt, ast.ImportFrom):
                if stmt.level == 0:
                    base = stmt.module or ""
                else:
                    here = mod.name.split(".")
                    if not is_package:
                        here = here[:-1]
                    here = here[: len(here) - (stmt.level - 1)]
                    base = ".".join(
                        here + (stmt.module.split(".") if stmt.module else [])
                    )
                if not base:
                    continue
                record(mod, base, stmt.lineno)
                for alias in stmt.names:
                    cand = base + "." + alias.name
                    if cand.startswith(pkg + ".") and cand in modules:
                        record(mod, cand, stmt.lineno)
    return modules


def _reachable(
    modules: dict[str, ModuleInfo], roots: Sequence[str]
) -> tuple[set[str], dict[str, str]]:
    """Modules import-reachable from ``roots`` + BFS parent pointers."""
    parent: dict[str, str] = {}
    seen = {r for r in roots if r in modules}
    queue = list(seen)
    while queue:
        cur = queue.pop(0)
        for dep, _ in modules[cur].internal:
            if dep not in seen:
                seen.add(dep)
                parent[dep] = cur
                queue.append(dep)
    return seen, parent


def _check_jax_free(
    modules: dict[str, ModuleInfo],
    roots: Sequence[str],
    code: str,
    contract: str,
) -> list[Diagnostic]:
    seen, parent = _reachable(modules, roots)
    diags: list[Diagnostic] = []
    for name in sorted(seen):
        mod = modules[name]
        if "jax" not in mod.external:
            continue
        chain = [name]
        while chain[-1] in parent:
            chain.append(parent[chain[-1]])
        diags.append(
            Diagnostic(
                code,
                f"jax is module-level reachable from {contract}: "
                + " -> ".join(reversed(chain)),
                provenance=(f"{mod.path}:{mod.external['jax']}: import jax",),
            )
        )
    return diags


def _is_write_call(node: ast.Call) -> bool:
    """``open(..., 'w'|'a'|'x'...)``, ``.open('w'...)``, ``.write_text`` /
    ``.write_bytes`` — the file-creating writes the atomicity rule covers."""
    func = node.func
    if isinstance(func, ast.Attribute) and func.attr in (
        "write_text",
        "write_bytes",
    ):
        return True
    is_open = (isinstance(func, ast.Name) and func.id == "open") or (
        isinstance(func, ast.Attribute) and func.attr == "open"
    )
    if not is_open:
        return False
    mode = None
    if len(node.args) >= 2 and isinstance(node.args[1], ast.Constant):
        mode = node.args[1].value
    elif len(node.args) == 1 and isinstance(func, ast.Attribute):
        if isinstance(node.args[0], ast.Constant):
            mode = node.args[0].value  # Path.open("w")
    for kw in node.keywords:
        if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
            mode = kw.value.value
    return isinstance(mode, str) and any(c in mode for c in "wax")


def _is_atomic_marker(node: ast.Call) -> bool:
    """``os.replace``/``os.rename``, ``mkstemp``, ``NamedTemporaryFile`` —
    evidence the enclosing function stages writes through a temp file."""
    func = node.func
    name = func.attr if isinstance(func, ast.Attribute) else (
        func.id if isinstance(func, ast.Name) else ""
    )
    return name in ("replace", "rename", "mkstemp", "NamedTemporaryFile")


def _check_atomic_writes(root: Path) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    for rel in _ATOMIC_WRITE_SCOPE:
        path = root / rel
        if not path.exists():
            continue
        try:
            tree = ast.parse(path.read_text(encoding="utf-8"))
        except (SyntaxError, OSError):
            continue
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            writes: list[int] = []
            atomic = False
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    if _is_write_call(node):
                        writes.append(node.lineno)
                    if _is_atomic_marker(node):
                        atomic = True
            if writes and not atomic:
                diags.append(
                    Diagnostic(
                        "R003",
                        f"{fn.name}() writes a file without temp+os.replace "
                        "staging; a reader can observe a torn write",
                        provenance=tuple(f"{path}:{ln}" for ln in writes),
                    )
                )
    return diags


def _check_serve_hot_path(
    modules: dict[str, ModuleInfo], pkg: str
) -> list[Diagnostic]:
    """R005: walk the module-level import closure of the serve hot path —
    skipping package ``__init__`` nodes, whose re-export edges would pull
    in the whole package surface — and flag any import of the shard
    machinery (direct or transitive through a traversed module)."""
    roots = [f"{pkg}.{m}" for m in _SERVE_HOT_ROOTS]
    banned = tuple(f"{pkg}.{m}" for m in _SERVE_BANNED_INTERNAL)

    def is_init(name: str) -> bool:
        mod = modules.get(name)
        return mod is not None and mod.path.name == "__init__.py"

    parent: dict[str, str] = {}
    seen = {r for r in roots if r in modules}
    queue = list(seen)
    while queue:
        cur = queue.pop(0)
        for dep, _ in modules[cur].internal:
            if dep not in seen and not is_init(dep):
                seen.add(dep)
                parent[dep] = cur
                queue.append(dep)

    diags: list[Diagnostic] = []
    flagged: set[tuple[str, str]] = set()
    for name in sorted(seen):
        mod = modules[name]
        chain = [name]
        while chain[-1] in parent:
            chain.append(parent[chain[-1]])
        via = " -> ".join(reversed(chain))
        for dep, lineno in mod.internal:
            if not any(dep == b or dep.startswith(b + ".") for b in banned):
                continue
            if (name, dep) in flagged:
                continue
            flagged.add((name, dep))
            diags.append(
                Diagnostic(
                    "R005",
                    f"serve hot path imports shard machinery {dep} "
                    f"(via {via}); per-request serving must stay free of "
                    "pool/shm/coordinator code",
                    provenance=(f"{mod.path}:{lineno}",),
                )
            )
        for base in _SERVE_BANNED_EXTERNAL:
            if base in mod.external and (name, base) not in flagged:
                flagged.add((name, base))
                diags.append(
                    Diagnostic(
                        "R005",
                        f"serve hot path imports {base} (via {via}); "
                        "per-request serving must stay single-process",
                        provenance=(f"{mod.path}:{mod.external[base]}",),
                    )
                )
    return diags


def _check_bare_except(root: Path) -> list[Diagnostic]:
    diags: list[Diagnostic] = []
    files: list[Path] = []
    for prefix in _BARE_EXCEPT_SCOPE:
        target = root / prefix
        if target.is_dir():
            files += sorted(target.rglob("*.py"))
        elif target.exists():
            files.append(target)
    for path in files:
        try:
            tree = ast.parse(path.read_text(encoding="utf-8"))
        except (SyntaxError, OSError):
            continue
        for node in ast.walk(tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                diags.append(
                    Diagnostic(
                        "R004",
                        "bare `except:` in executor/runtime code swallows "
                        "KeyboardInterrupt/SystemExit; catch Exception (or "
                        "narrower)",
                        provenance=(f"{path}:{node.lineno}",),
                    )
                )
    return diags


def lint_contracts(
    root: str | Path, rules: Sequence[str] | None = None
) -> list[Diagnostic]:
    """Run the contract rules over a package directory (e.g.
    ``src/repro``). ``rules`` selects a subset (default: all)."""
    root = Path(root).resolve()
    pkg = root.name
    active = tuple(rules) if rules else ALL_RULES
    diags: list[Diagnostic] = []
    if "R001" in active or "R002" in active or "R005" in active:
        modules = build_import_graph(root)
        if "R001" in active:
            diags += _check_jax_free(
                modules,
                [f"{pkg}.{m}" for m in _WORKER_TIER_ROOTS],
                "R001",
                "the jax-free worker tier (distributed.worker/transport)",
            )
        if "R002" in active:
            diags += _check_jax_free(
                modules,
                [f"{pkg}.{m}" for m in _FORK_SIDE_ROOTS],
                "R002",
                "a fork-side bytes path (core.bytesops/executor/pipeline)",
            )
        if "R005" in active:
            diags += _check_serve_hot_path(modules, pkg)
    if "R003" in active:
        diags += _check_atomic_writes(root)
    if "R004" in active:
        diags += _check_bare_except(root)
    return diags
