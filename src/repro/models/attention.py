"""Multi-head attention: GQA, RoPE/M-RoPE, sliding window, KV cache.

The jnp reference path is what the distributed dry-run lowers (XLA SPMD
shards it); the Pallas flash kernel (repro.kernels.flash_attention) is the
TPU hot-path alternative, validated against this in tests and selectable
via ``use_flash``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import apply_mrope, apply_rope, truncated_normal

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (batch, max_seq, n_kv_heads, head_dim)
    v: jax.Array


def init_attention(key, cfg, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads, cfg.n_kv_heads
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = cfg.init_scale / np.sqrt(d)
    p = {
        "wq": truncated_normal(kq, (d, nq, hd), dtype, s),
        "wk": truncated_normal(kk, (d, nkv, hd), dtype, s),
        "wv": truncated_normal(kv, (d, nkv, hd), dtype, s),
        "wo": truncated_normal(ko, (nq, hd, d), dtype, cfg.init_scale / np.sqrt(nq * hd)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((nq, hd), dtype)
        p["bk"] = jnp.zeros((nkv, hd), dtype)
        p["bv"] = jnp.zeros((nkv, hd), dtype)
    return p


def attention_axes(cfg) -> dict:
    p = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = ("heads", "head_dim")
        p["bk"] = ("kv_heads", "head_dim")
        p["bv"] = ("kv_heads", "head_dim")
    return p


def _project_qkv(p: dict, x: jax.Array, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _rope(q, k, positions, cfg):
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        hd = cfg.resolved_head_dim // 2
        # Qwen2-VL-style section split over half-dim (t, h, w)
        sections = (hd - 2 * (hd // 4), hd // 4, hd // 4)
        pos3 = mrope_positions(positions, cfg)
        q = apply_mrope(q, pos3, sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, sections, cfg.rope_theta)
    return q, k


def mrope_positions(positions: jax.Array, cfg) -> jax.Array:
    """(3, b, s) temporal/height/width positions. The leading
    ``n_frontend_tokens`` positions are image patches on a
    sqrt-grid (dynamic-resolution stub); the rest is text (t=h=w)."""
    n_img = cfg.n_frontend_tokens if cfg.frontend == "vision" else 0
    grid = max(int(np.sqrt(max(n_img, 1))), 1)
    is_img = positions < n_img
    h = jnp.where(is_img, (positions % (grid * grid)) // grid, positions)
    w = jnp.where(is_img, positions % grid, positions)
    t = jnp.where(is_img, 0, positions)
    return jnp.stack([t, h, w])


def sdpa(
    q: jax.Array,  # (b, sq, nq, hd)
    k: jax.Array,  # (b, skv, nkv, hd)
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    k_positions: jax.Array | None = None,  # absolute key positions (ring cache)
) -> jax.Array:
    """Grouped-query SDPA with optional causal mask, sliding window and
    KV-cache length masking. fp32 softmax."""
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    groups = nq // nkv
    qg = q.reshape(b, sq, nkv, groups, hd)
    scores = jnp.einsum("bsngk,btnk->bngst", qg, k).astype(jnp.float32)
    scores = scores / np.sqrt(hd)

    q_pos = jnp.arange(sq)[:, None] + q_offset  # absolute query positions
    k_pos = (k_positions if k_positions is not None else jnp.arange(skv))[None, :]
    mask = k_pos >= 0
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    if kv_len is not None:
        mask &= k_pos < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bngst,btnk->bsngk", probs, v)
    return out.reshape(b, sq, nq, hd)


def chunked_sdpa(
    q: jax.Array,  # (b, sq, nq, hd)
    k: jax.Array,  # (b, skv, nkv, hd)
    v: jax.Array,
    *,
    causal: bool,
    window: int = 0,
    q_offset: jax.Array | int = 0,
    kv_len: jax.Array | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (scan over q and kv
    chunks). Never materializes the (sq, skv) score matrix — this is what
    makes 32k prefill / 4k train lowerable at production batch sizes. The
    Pallas kernel (repro.kernels.flash_attention) is the TPU twin."""
    b, sq, nq, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = nq // nkv
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq_chunks = (sq + q_chunk - 1) // q_chunk
    nkv_chunks = (skv + kv_chunk - 1) // kv_chunk
    pad_q = nq_chunks * q_chunk - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    pad_kv = nkv_chunks * kv_chunk - skv
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    eff_kv_len = jnp.asarray(kv_len if kv_len is not None else skv)

    qg = q.reshape(b, nq_chunks, q_chunk, nkv, g, hd)
    qg = jnp.moveaxis(qg, 1, 0)  # (nQ, b, qc, nkv, g, hd)
    kc = jnp.moveaxis(k.reshape(b, nkv_chunks, kv_chunk, nkv, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nkv_chunks, kv_chunk, nkv, hd), 1, 0)
    scale = 1.0 / np.sqrt(hd)

    def q_body(carry, inp):
        qi, q_blk = inp  # q_blk: (b, qc, nkv, g, hd)
        q_pos = qi * q_chunk + jnp.arange(q_chunk) + q_offset

        def kv_body(state, kv_inp):
            m, l_sum, acc = state
            kj, k_blk, v_blk = kv_inp
            k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqngk,btnk->bngqt", q_blk, k_blk).astype(jnp.float32) * scale
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= k_pos[None, :] <= q_pos[:, None]
            if window > 0:
                mask &= k_pos[None, :] > q_pos[:, None] - window
            mask &= k_pos[None, :] < eff_kv_len
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_sum_new = l_sum * corr + p.sum(-1)
            pv = jnp.einsum("bngqt,btnk->bngqk", p.astype(v_blk.dtype), v_blk)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
            return (m_new, l_sum_new, acc_new), None

        m0 = jnp.full((b, nkv, g, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, nkv, g, q_chunk, hd), jnp.float32)
        (m, l_sum, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(nkv_chunks), kc, vc)
        )
        out = acc / jnp.maximum(l_sum, 1e-30)[..., None]
        out = jnp.moveaxis(out, 3, 1)  # (b, qc, nkv, g, hd)
        return carry, out.astype(q_blk.dtype)

    _, outs = jax.lax.scan(q_body, None, (jnp.arange(nq_chunks), qg))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, nq_chunks * q_chunk, nkv, g, hd)
    if pad_q:
        out = out[:, :sq]
    return out.reshape(b, sq, nq, hd)


# jnp attention dispatch: naive path keeps the oracle simple for short
# sequences; long sequences must never materialize (sq, skv).
CHUNKED_THRESHOLD = 2048


def dispatch_sdpa(q, k, v, *, q_chunk: int = 512, kv_chunk: int = 1024, **kw):
    sq, skv = q.shape[1], k.shape[1]
    if sq * skv > CHUNKED_THRESHOLD * CHUNKED_THRESHOLD or sq > CHUNKED_THRESHOLD:
        # q_chunk == 0: kv-only streaming (sequence-parallel plan — the
        # query seq axis may be mesh-sharded and must not be re-chunked)
        return chunked_sdpa(
            q, k, v, q_chunk=(q_chunk or sq), kv_chunk=kv_chunk, **kw
        )
    return sdpa(q, k, v, **kw)


def attend(
    p: dict,
    x: jax.Array,
    cfg,
    *,
    positions: jax.Array,
    cache: KVCache | None = None,
    cache_pos: jax.Array | int = 0,
) -> tuple[jax.Array, KVCache | None]:
    """Full attention sub-layer. With ``cache`` set, performs decode-style
    cache update (x is the new token block) and attends over the cache."""
    q, k, v = _project_qkv(p, x, cfg)
    q, k = _rope(q, k, positions, cfg)
    chunks = dict(q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    if cache is None:
        out = dispatch_sdpa(q, k, v, causal=cfg.causal, window=cfg.window, **chunks)
        new_cache = None
    elif cfg.window > 0 and cache.k.shape[1] <= cfg.window:
        out, new_cache = _ring_attend(q, k, v, cache, cache_pos, cfg, chunks)
    else:
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, cache_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, cache_pos, 0, 0))
        kv_len = cache_pos + x.shape[1]
        out = dispatch_sdpa(
            q, ck, cv,
            causal=cfg.causal, window=cfg.window,
            q_offset=cache_pos, kv_len=kv_len, **chunks,
        )
        new_cache = KVCache(ck, cv)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def _ring_attend(q, k, v, cache: KVCache, cache_pos, cfg, chunks):
    """Sliding-window ring-buffer KV cache (beyond-paper §Perf): the cache
    holds only the last `window` keys (exact — windowed attention never
    reads older ones). Slot for absolute position P is P % window; slot i
    currently holds position cache_len-1 - ((cache_len-1 - i) % window).

    Block prefill (s > 1) is supported at cache_pos == 0: in-block windowed
    attention + write the trailing `window` tokens into the ring."""
    w = cache.k.shape[1]
    s = q.shape[1]
    if s == 1:
        slot = cache_pos % w
        ck = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype), (0, slot, 0, 0))
        i = jnp.arange(w)
        k_positions = cache_pos - ((cache_pos - i) % w)  # absolute pos per slot
        out = sdpa(
            q, ck, cv, causal=cfg.causal, window=cfg.window,
            q_offset=cache_pos, k_positions=k_positions,
        )
        return out, KVCache(ck, cv)
    # block prefill
    out = dispatch_sdpa(q, k, v, causal=cfg.causal, window=cfg.window, **chunks)
    take = min(w, s)
    tail_k = k[:, s - take :].astype(cache.k.dtype)
    tail_v = v[:, s - take :].astype(cache.v.dtype)
    slots = (jnp.arange(s - take, s) % w)
    ck = cache.k.at[:, slots].set(tail_k)
    cv = cache.v.at[:, slots].set(tail_v)
    return out, KVCache(ck, cv)


def init_kv_cache(batch: int, max_seq: int, cfg, dtype=jnp.bfloat16) -> KVCache:
    ring = cfg.window > 0 and getattr(cfg, "ring_kv", True)
    seq = min(max_seq, cfg.window) if ring else max_seq
    shape = (batch, seq, cfg.n_kv_heads, cfg.resolved_head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
