"""The paper's case study: LSTM seq2seq title generation with Bahdanau
attention (paper §4.2.3, Figs. 4-6, Algorithm 3).

Faithful structure:
* 3-layer stacked LSTM encoder over the cleaned abstract (paper: "a 3-layer
  stacked LSTM is used for encoder").
* single-layer LSTM decoder initialized from the encoder's final
  hidden/cell states.
* Bahdanau additive attention (paper eqs. 1-5): e_ij = v^T tanh(W_s s_i +
  W_h h_j); a_ij = softmax; C_i = sum_j a_ij h_j; S_i = [s_i; C_i];
  y_i = dense(S_i).
* Training predicts the target sequence offset by one time-step (teacher
  forcing); inference is greedy argmax until <end> or max length
  (Algorithm 3).

Pure JAX: ``jax.lax.scan`` over time; the LSTM cell matches the fused
Pallas kernel (repro.kernels.lstm_cell) bit-for-bit at fp32.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..data.tokenizer import END, PAD, START
from .blocks import truncated_normal


@dataclass(frozen=True)
class Seq2SeqConfig:
    vocab_size: int
    d_embed: int = 128
    d_hidden: int = 256
    n_encoder_layers: int = 3
    max_abstract_len: int = 128
    max_title_len: int = 24
    init_scale: float = 0.08


class LSTMState(NamedTuple):
    h: jax.Array
    c: jax.Array


# ---------------------------------------------------------------------------
# LSTM cell (the jnp twin of kernels/lstm_cell)
# ---------------------------------------------------------------------------


def init_lstm_layer(key, d_in: int, d_hidden: int, scale: float, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "wx": truncated_normal(k1, (d_in, 4 * d_hidden), dtype, scale / np.sqrt(d_in)),
        "wh": truncated_normal(k2, (d_hidden, 4 * d_hidden), dtype, scale / np.sqrt(d_hidden)),
        "b": jnp.zeros((4 * d_hidden,), dtype),
    }


def lstm_cell(p: dict, x_t: jax.Array, state: LSTMState) -> LSTMState:
    """Standard LSTM cell; gate order (i, f, g, o). fp32 gate math."""
    z = (x_t @ p["wx"] + state.h @ p["wh"] + p["b"]).astype(jnp.float32)
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * state.c.astype(jnp.float32) + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return LSTMState(h.astype(x_t.dtype), c.astype(x_t.dtype))


def lstm_scan(p: dict, xs: jax.Array, state: LSTMState) -> tuple[jax.Array, LSTMState]:
    """xs: (b, s, d) -> (hs (b, s, H), final_state)."""

    def step(st, x_t):
        st = lstm_cell(p, x_t, st)
        return st, st.h

    final, hs = jax.lax.scan(step, state, jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1), final


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Seq2Seq:
    def __init__(self, cfg: Seq2SeqConfig, dtype=jnp.float32):
        self.cfg = cfg
        self.dtype = dtype

    def init(self, key) -> dict:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 8 + cfg.n_encoder_layers)
        enc_layers = []
        d_in = cfg.d_embed
        for i in range(cfg.n_encoder_layers):
            enc_layers.append(init_lstm_layer(ks[i], d_in, cfg.d_hidden, cfg.init_scale, dt))
            d_in = cfg.d_hidden
        j = cfg.n_encoder_layers
        s = cfg.init_scale
        return {
            "embed_enc": truncated_normal(ks[j], (cfg.vocab_size, cfg.d_embed), dt, 1.0),
            "embed_dec": truncated_normal(ks[j + 1], (cfg.vocab_size, cfg.d_embed), dt, 1.0),
            "encoder": enc_layers,
            "decoder": init_lstm_layer(ks[j + 2], cfg.d_embed, cfg.d_hidden, s, dt),
            # Bahdanau attention (paper eq. 1-2)
            "attn_ws": truncated_normal(ks[j + 3], (cfg.d_hidden, cfg.d_hidden), dt, s / np.sqrt(cfg.d_hidden)),
            "attn_wh": truncated_normal(ks[j + 4], (cfg.d_hidden, cfg.d_hidden), dt, s / np.sqrt(cfg.d_hidden)),
            "attn_v": truncated_normal(ks[j + 5], (cfg.d_hidden,), dt, s / np.sqrt(cfg.d_hidden)),
            # output dense over [s_i; C_i] (paper eq. 4-5)
            "out_w": truncated_normal(ks[j + 6], (2 * cfg.d_hidden, cfg.vocab_size), dt, s / np.sqrt(2 * cfg.d_hidden)),
            "out_b": jnp.zeros((cfg.vocab_size,), dt),
        }

    # -- encoder -----------------------------------------------------------
    def encode(self, params: dict, enc_tokens: jax.Array):
        """Returns (enc_hs (b, s, H), final_state, enc_mask (b, s))."""
        cfg = self.cfg
        x = jnp.take(params["embed_enc"], enc_tokens, axis=0)
        b = x.shape[0]
        state = LSTMState(
            jnp.zeros((b, cfg.d_hidden), x.dtype), jnp.zeros((b, cfg.d_hidden), x.dtype)
        )
        hs = x
        for layer in params["encoder"]:
            hs, state = lstm_scan(layer, hs, LSTMState(jnp.zeros_like(state.h), jnp.zeros_like(state.c)))
        mask = (enc_tokens != PAD)
        return hs, state, mask

    # -- Bahdanau attention --------------------------------------------------
    def _attend(self, params: dict, s_i: jax.Array, enc_hs: jax.Array, enc_mask: jax.Array):
        """s_i: (b, H); enc_hs: (b, s, H) -> context (b, H)."""
        proj = (s_i @ params["attn_ws"])[:, None, :] + enc_hs @ params["attn_wh"]
        e = jnp.tanh(proj.astype(jnp.float32)) @ params["attn_v"].astype(jnp.float32)  # (b, s)
        e = jnp.where(enc_mask, e, -1e30)
        a = jax.nn.softmax(e, axis=-1).astype(enc_hs.dtype)
        return jnp.einsum("bs,bsh->bh", a, enc_hs)

    # -- training forward (teacher forcing) ----------------------------------
    def forward(self, params: dict, batch: dict) -> jax.Array:
        """batch: encoder_tokens (b, S), decoder_tokens (b, T).
        Returns logits (b, T-1, V) predicting decoder_tokens[:, 1:]."""
        enc_hs, state, enc_mask = self.encode(params, batch["encoder_tokens"])
        dec_in = batch["decoder_tokens"][:, :-1]
        x = jnp.take(params["embed_dec"], dec_in, axis=0)

        def step(st, x_t):
            st = lstm_cell(params["decoder"], x_t, st)
            ctx = self._attend(params, st.h, enc_hs, enc_mask)
            s_cat = jnp.concatenate([st.h, ctx], axis=-1)
            logits = s_cat @ params["out_w"] + params["out_b"]
            return st, logits

        _, logits = jax.lax.scan(step, state, jnp.moveaxis(x, 1, 0))
        return jnp.moveaxis(logits, 0, 1)

    def loss(self, params: dict, batch: dict) -> jax.Array:
        logits = self.forward(params, batch).astype(jnp.float32)
        targets = batch["decoder_tokens"][:, 1:]
        mask = (targets != PAD).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)

    # -- inference (paper Algorithm 3: greedy decode) -------------------------
    def generate(self, params: dict, enc_tokens: jax.Array, max_len: int | None = None):
        cfg = self.cfg
        max_len = max_len or cfg.max_title_len
        enc_hs, state, enc_mask = self.encode(params, enc_tokens)
        b = enc_tokens.shape[0]

        def step(carry, _):
            st, tok, done = carry
            x_t = jnp.take(params["embed_dec"], tok, axis=0)
            st = lstm_cell(params["decoder"], x_t, st)
            ctx = self._attend(params, st.h, enc_hs, enc_mask)
            logits = jnp.concatenate([st.h, ctx], axis=-1) @ params["out_w"] + params["out_b"]
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            nxt = jnp.where(done, PAD, nxt)
            done = done | (nxt == END)
            return (st, nxt, done), nxt

        init = (state, jnp.full((b,), START, jnp.int32), jnp.zeros((b,), bool))
        _, toks = jax.lax.scan(step, init, None, length=max_len)
        return jnp.moveaxis(toks, 0, 1)  # (b, max_len)
