"""Shared model building blocks (pure-JAX, functional params-as-pytrees).

Conventions
-----------
* Every parameter leaf is annotated in the matching ``*_axes`` pytree with a
  tuple of *logical axis names* (see repro.distributed.sharding). ``None``
  means replicated along that dim.
* Compute dtype follows the input; norms/softmax accumulate in fp32.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, dtype, scale: float):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, dtype) -> dict:
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def norm_axes(cfg) -> dict:
    p = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        p["bias"] = ("embed",)
    return p


def apply_norm(p: dict, x: jax.Array, kind: str) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6)
        return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (GLU or plain)
# ---------------------------------------------------------------------------

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(key, cfg, d_ff: int | None = None, dtype=jnp.bfloat16) -> dict:
    d_ff = d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    scale = cfg.init_scale / np.sqrt(cfg.d_model)
    p = {"down": truncated_normal(k2, (d_ff, cfg.d_model), dtype, cfg.init_scale / np.sqrt(d_ff))}
    if cfg.glu:
        p["gate"] = truncated_normal(k1, (cfg.d_model, d_ff), dtype, scale)
        p["up"] = truncated_normal(k3, (cfg.d_model, d_ff), dtype, scale)
    else:
        p["up"] = truncated_normal(k1, (cfg.d_model, d_ff), dtype, scale)
    return p


def mlp_axes(cfg) -> dict:
    p = {"down": ("mlp", "embed"), "up": ("embed", "mlp")}
    if cfg.glu:
        p["gate"] = ("embed", "mlp")
    return p


def apply_mlp(p: dict, x: jax.Array, cfg) -> jax.Array:
    act = _ACTS[cfg.act]
    if cfg.glu:
        h = act(x @ p["gate"]) * (x @ p["up"])
    else:
        h = act(x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def init_embed(key, cfg, dtype) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"embedding": truncated_normal(k1, (cfg.vocab_size, cfg.d_model), dtype, 1.0)}
    if not cfg.tie_embeddings:
        p["lm_head"] = truncated_normal(
            k2, (cfg.d_model, cfg.vocab_size), dtype, cfg.init_scale / np.sqrt(cfg.d_model)
        )
    return p


def embed_axes(cfg) -> dict:
    p = {"embedding": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["lm_head"] = ("embed", "vocab")
    return p


def embed_tokens(p: dict, tokens: jax.Array, cfg) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    return x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)


def lm_logits(p: dict, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        # un-scale: embed_tokens multiplied by sqrt(d); keep logits O(1)
        return (x @ p["embedding"].T) / jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x @ p["lm_head"]


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., s, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions_3d: jax.Array, sections: tuple[int, int, int], theta: float = 1e6
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    ``positions_3d``: (3, ..., seq) temporal/height/width position streams.
    ``sections``: frequency-split sizes (in half-dim units) per stream.
    For pure-text positions all three streams are equal, which reduces to
    standard RoPE.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = jnp.asarray(rope_frequencies(head_dim, theta), jnp.float32)  # (half,)
    # stream id per frequency slot
    sid = np.zeros(half, dtype=np.int32)
    sid[sections[0] : sections[0] + sections[1]] = 1
    sid[sections[0] + sections[1] :] = 2
    pos = jnp.take(positions_3d, jnp.asarray(sid), axis=0)  # (half, ..., seq)
    pos = jnp.moveaxis(pos, 0, -1)  # (..., seq, half)
    angles = pos[..., None, :].astype(jnp.float32) * freqs  # (..., seq, 1, half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, targets: jax.Array, mask: jax.Array) -> jax.Array:
    """Mean CE over mask (fp32 accumulation)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
