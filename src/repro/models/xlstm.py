"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM.

Both use exponential gating with a log-domain stabilizer ``m_t``:

mLSTM (per head, head dim ``dh``):
    m_t = max(f~_t + m_{t-1}, i~_t)
    i'  = exp(i~_t - m_t);  f' = exp(f~_t + m_{t-1} - m_t)
    C_t = f' C_{t-1} + i' v_t k_t^T
    n_t = f' n_{t-1} + i' k_t
    h~  = C_t q_t / max(|n_t . q_t|, 1)

sLSTM (per unit):
    same stabilized gating on scalar memory c_t, normalizer n_t, with
    recurrent gate contributions from h_{t-1}.

Training/prefill runs ``jax.lax.scan`` over time (compiles to a single
step body — sub-quadratic in sequence length); decode is one step.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import truncated_normal


class MLSTMState(NamedTuple):
    c: jax.Array  # (b, H, dh, dh)
    n: jax.Array  # (b, H, dh)
    m: jax.Array  # (b, H)


class SLSTMState(NamedTuple):
    c: jax.Array  # (b, dr)
    n: jax.Array  # (b, dr)
    m: jax.Array  # (b, dr)
    h: jax.Array  # (b, dr) previous output (recurrent gates)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype) -> dict:
    d, dr, h = cfg.d_model, cfg.resolved_d_rnn, cfg.n_heads
    ku, kq, kk, kv, kg, kd = jax.random.split(key, 6)
    s = cfg.init_scale / np.sqrt(d)
    sr = cfg.init_scale / np.sqrt(dr)
    return {
        "w_up": truncated_normal(ku, (d, 2 * dr), dtype, s),
        "w_q": truncated_normal(kq, (dr, dr), dtype, sr),
        "w_k": truncated_normal(kk, (dr, dr), dtype, sr),
        "w_v": truncated_normal(kv, (dr, dr), dtype, sr),
        "w_if": truncated_normal(kg, (dr, 2 * h), dtype, sr),
        "b_if": jnp.concatenate([jnp.zeros((h,)), jnp.ones((h,)) * 3.0]).astype(dtype),
        "w_down": truncated_normal(kd, (dr, d), dtype, sr),
    }


def mlstm_axes(cfg) -> dict:
    return {
        "w_up": ("embed", "rnn"),
        "w_q": ("rnn_in", "rnn"),
        "w_k": ("rnn_in", "rnn"),
        "w_v": ("rnn_in", "rnn"),
        "w_if": ("rnn_in", None),
        "b_if": (None,),
        "w_down": ("rnn", "embed"),
    }


def _mlstm_inputs(p: dict, x: jax.Array, cfg):
    d, dr, H = cfg.d_model, cfg.resolved_d_rnn, cfg.n_heads
    dh = dr // H
    up = x @ p["w_up"]
    u, z = jnp.split(up, 2, axis=-1)  # (b,s,dr) each
    q = (u @ p["w_q"]).reshape(*u.shape[:-1], H, dh)
    k = (u @ p["w_k"]).reshape(*u.shape[:-1], H, dh) / np.sqrt(dh)
    v = (u @ p["w_v"]).reshape(*u.shape[:-1], H, dh)
    gates = (u @ p["w_if"] + p["b_if"]).astype(jnp.float32)  # (b,s,2H)
    i_t, f_t = jnp.split(gates, 2, axis=-1)
    return q, k, v, i_t, f_t, z


def _mlstm_step(state: MLSTMState, qkvif) -> tuple[MLSTMState, jax.Array]:
    q, k, v, i_t, f_t = qkvif  # q,k,v: (b,H,dh); i,f: (b,H)
    qf, kf, vf = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(f_log + state.m, i_t)
    i_p = jnp.exp(i_t - m_new)
    f_p = jnp.exp(f_log + state.m - m_new)
    c = f_p[..., None, None] * state.c + i_p[..., None, None] * (
        vf[..., :, None] * kf[..., None, :]
    )
    n = f_p[..., None] * state.n + i_p[..., None] * kf
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    h = jnp.einsum("bhvk,bhk->bhv", c, qf) / denom[..., None]
    return MLSTMState(c, n, m_new), h


# Sequence length from which the chunkwise formulation takes over. The
# per-timestep scan materializes the (dh x dh) matrix memory every step —
# O(s * dh^2) HBM traffic; the chunkwise form (identical math, see
# _mlstm_chunk) materializes state once per chunk: O(s/L * dh^2) + an
# O(s * L * dh) intra-chunk attention-like term. EXPERIMENTS.md §Perf
# records the measured effect on the xlstm train_4k cell.
CHUNK = 64


def mlstm_scan(p, x, cfg, state: MLSTMState | None = None):
    b, s = x.shape[0], x.shape[1]
    if state is None:
        state = init_mlstm_state(b, cfg)
    if s >= 2 * CHUNK and s % CHUNK == 0:
        return _mlstm_chunked(p, x, cfg, state, CHUNK)
    q, k, v, i_t, f_t, z = _mlstm_inputs(p, x, cfg)
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (q, k, v, i_t, f_t))
    final, hs = jax.lax.scan(_mlstm_step, state, xs)
    hs = jnp.moveaxis(hs, 0, 1)  # (b, s, H, dh)
    hs = hs.reshape(*hs.shape[:2], -1).astype(x.dtype)
    y = (hs * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) @ p["w_down"]
    return y, final


def _mlstm_chunked(p, x, cfg, state: MLSTMState, L: int):
    """Chunkwise-parallel mLSTM — exactly the per-step recurrence with the
    stabilizer algebra unrolled per chunk:

        m_t   = max(b_t + m_in, max_{j<=t}(b_t - b_j + i_j))
        num_t = e^{b_t+m_in-m_t} C_in q_t + sum_j e^{b_t-b_j+i_j-m_t}(k_j.q_t) v_j
        den_t = same with n_in / k_j
        h_t   = num_t / max(|den_t|, 1)

    (b_t = cumulative log-forget within the chunk; states carry the
    exp(m) normalization exactly like the sequential scan)."""
    b, s = x.shape[0], x.shape[1]
    H = cfg.n_heads
    q, k, v, i_t, f_t, z = _mlstm_inputs(p, x, cfg)
    dh = q.shape[-1]
    nC = s // L

    # (b, s, H, dh) -> (nC, b, H, L, dh); gates (b, s, H) -> (nC, b, H, L)
    def chunk_qkv(a):
        return jnp.moveaxis(a.reshape(b, nC, L, H, dh), (1, 3), (0, 2))

    def chunk_g(a):
        return jnp.moveaxis(a.reshape(b, nC, L, H), (1, 3), (0, 2))

    qc, kc, vc = chunk_qkv(q.astype(jnp.float32)), chunk_qkv(k.astype(jnp.float32)), chunk_qkv(v.astype(jnp.float32))
    ic, fc = chunk_g(i_t), chunk_g(f_t)

    def chunk_step(carry, inp):
        C_in, n_in, m_in = carry
        qb, kb, vb, ib, fb = inp  # (b,H,L,dh) / (b,H,L)
        lf = jax.nn.log_sigmoid(fb)
        b_cum = jnp.cumsum(lf, axis=-1)  # (b,H,L)
        # running max of (i_j - b_j) over j<=t
        rmax = jax.lax.cummax(ib - b_cum, axis=2)
        m_t = jnp.maximum(b_cum + m_in[..., None], rmax + b_cum)
        inter = jnp.exp(b_cum + m_in[..., None] - m_t)  # (b,H,L)
        # intra decay matrix D (b,H,L,L): exp(b_t - m_t + i_j - b_j), j<=t
        D = jnp.exp(
            (b_cum - m_t)[..., :, None] + (ib - b_cum)[..., None, :]
        )
        mask = jnp.tril(jnp.ones((L, L), bool))
        D = jnp.where(mask, D, 0.0)
        scores = jnp.einsum("bhld,bhmd->bhlm", qb, kb)  # (b,H,L,L) t x j
        W = D * scores
        # C layout matches the sequential scan: C[v_dim, k_dim]
        num = inter[..., None] * jnp.einsum("bhld,bhvd->bhlv", qb, C_in) \
            + jnp.einsum("bhlm,bhmv->bhlv", W, vb)
        den = inter * jnp.einsum("bhld,bhd->bhl", qb, n_in) + W.sum(-1)
        h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]  # (b,H,L,dh)
        # state update (same algebra at t = L-1)
        m_out = jnp.maximum(b_cum[..., -1] + m_in, rmax[..., -1] + b_cum[..., -1])
        s_out = jnp.exp(b_cum[..., -1] + m_in - m_out)  # (b,H)
        w_j = jnp.exp((b_cum[..., -1:] - b_cum) + ib - m_out[..., None])  # (b,H,L)
        C_out = s_out[..., None, None] * C_in + jnp.einsum("bhl,bhld,bhlv->bhvd", w_j, kb, vb)
        n_out = s_out[..., None] * n_in + jnp.einsum("bhl,bhld->bhd", w_j, kb)
        return (C_out, n_out, m_out), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (state.c, state.n, state.m), (qc, kc, vc, ic, fc)
    )
    # hs: (nC, b, H, L, dh) -> (b, s, H*dh)
    hs = jnp.moveaxis(hs, (0, 3), (1, 2)).reshape(b, s, H * dh).astype(x.dtype)
    y = (hs * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) @ p["w_down"]
    return y, MLSTMState(C, n, m)


def init_mlstm_state(batch: int, cfg) -> MLSTMState:
    dr, H = cfg.resolved_d_rnn, cfg.n_heads
    dh = dr // H
    return MLSTMState(
        c=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype) -> dict:
    d, dr = cfg.d_model, cfg.resolved_d_rnn
    kw, kr, kd = jax.random.split(key, 3)
    s = cfg.init_scale / np.sqrt(d)
    sr = cfg.init_scale / np.sqrt(dr)
    return {
        "w": truncated_normal(kw, (d, 4 * dr), dtype, s),  # i,f,z,o from input
        "r": truncated_normal(kr, (dr, 4 * dr), dtype, sr),  # recurrent
        "b": jnp.zeros((4 * dr,), dtype),
        "w_down": truncated_normal(kd, (dr, d), dtype, sr),
    }


def slstm_axes(cfg) -> dict:
    return {
        "w": ("embed", "rnn"),
        "r": ("rnn_in", "rnn"),
        "b": ("rnn",),
        "w_down": ("rnn", "embed"),
    }


def _slstm_step_factory(p):
    r = p["r"].astype(jnp.float32)

    def step(state: SLSTMState, wx_t):
        pre = wx_t.astype(jnp.float32) + state.h @ r
        i_t, f_t, z_t, o_t = jnp.split(pre, 4, axis=-1)
        f_log = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(f_log + state.m, i_t)
        i_p = jnp.exp(i_t - m_new)
        f_p = jnp.exp(f_log + state.m - m_new)
        c = f_p * state.c + i_p * jnp.tanh(z_t)
        n = f_p * state.n + i_p
        h = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        return SLSTMState(c, n, m_new, h), h

    return step


def slstm_scan(p, x, cfg, state: SLSTMState | None = None):
    b = x.shape[0]
    if state is None:
        state = init_slstm_state(b, cfg)
    wx = x @ p["w"] + p["b"]  # (b, s, 4dr)
    final, hs = jax.lax.scan(_slstm_step_factory(p), state, jnp.moveaxis(wx, 1, 0))
    hs = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    return hs @ p["w_down"], final


def init_slstm_state(batch: int, cfg) -> SLSTMState:
    dr = cfg.resolved_d_rnn
    z = jnp.zeros((batch, dr), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full((batch, dr), -1e30, jnp.float32), h=z)
