"""Fine-grained Mixture-of-Experts (DeepSeekMoE / Kimi-K2 style).

Routed experts + optional shared experts. Two execution paths:

* **local** — single-device/CPU smoke path: sort tokens by expert, one
  ragged (grouped) GEMM per projection (``jax.lax.ragged_dot``).
* **expert-parallel (EP)** — production path inside ``jax.shard_map``:
  experts are sharded over the ``model`` mesh axis; each data shard routes
  its tokens, packs capacity-bounded per-owner send buffers, exchanges them
  with ``all_to_all``, runs the ragged expert GEMMs on its expert slice,
  and reverses the exchange before the weighted combine. Token dropping
  beyond capacity follows standard practice (GShard/Switch); dropped slots
  are masked out of the combine. Shared experts run as a plain dense GLU
  outside the shard_map (tensor-parallel via pjit like any MLP).

The routed output is replicated over the model axis by construction (every
model rank sends identical buffers), so ``check_vma=False`` is used and the
combine result carries data-parallel sharding only.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import truncated_normal

# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.d_expert
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s = cfg.init_scale / np.sqrt(d)
    p = {
        "router": truncated_normal(kr, (d, m.n_experts), jnp.float32, s),
        "w_gate": truncated_normal(kg, (m.n_experts, d, f), dtype, s),
        "w_up": truncated_normal(ku, (m.n_experts, d, f), dtype, s),
        "w_down": truncated_normal(kd, (m.n_experts, f, d), dtype, cfg.init_scale / np.sqrt(f)),
    }
    if m.n_shared:
        ks1, ks2, ks3 = jax.random.split(ks, 3)
        fs = m.n_shared * f
        p["shared"] = {
            "gate": truncated_normal(ks1, (d, fs), dtype, s),
            "up": truncated_normal(ks2, (d, fs), dtype, s),
            "down": truncated_normal(ks3, (fs, d), dtype, cfg.init_scale / np.sqrt(fs)),
        }
    return p


def moe_axes(cfg) -> dict:
    p = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_ff"),
        "w_up": ("experts", "embed", "expert_ff"),
        "w_down": ("experts", "expert_ff", "embed"),
    }
    if cfg.moe.n_shared:
        p["shared"] = {"gate": ("embed", "mlp"), "up": ("embed", "mlp"), "down": ("mlp", "embed")}
    return p


# ---------------------------------------------------------------------------
# Routing
# ---------------------------------------------------------------------------


def _route(xf: jax.Array, router: jax.Array, m) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (expert_ids (N,k), probs (N,k), aux_loss)."""
    logits = (xf.astype(jnp.float32) @ router).astype(jnp.float32)  # (N, E)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, ids = jax.lax.top_k(probs_full, m.top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)  # renorm (DeepSeek)
    # Switch/GShard load-balance aux: E * sum_e f_e * P_e
    pe = probs_full.mean(axis=0)
    fe = jnp.zeros((m.n_experts,), jnp.float32).at[ids.reshape(-1)].add(1.0)
    fe = fe / jnp.maximum(fe.sum(), 1.0)
    aux = m.n_experts * jnp.sum(fe * pe)
    return ids, probs.astype(xf.dtype), aux


def _expert_ffn(tokens: jax.Array, eids: jax.Array, p: dict, n_experts: int,
                impl: str = "ragged", capacity_factor: float = 1.5) -> jax.Array:
    """Grouped expert GLU-FFN over tokens labelled by ``eids``.

    ``eids == n_experts`` marks invalid/padding rows (zero output).

    impl="ragged": ``jax.lax.ragged_dot`` x3. Semantically exact (no
    second-level dropping) but XLA's dense lowering multiplies FLOPs by
    the local expert count — fine on backends with native grouped GEMM.

    impl="batched": capacity-bounded scatter into an (E, cap, d) buffer +
    three *batched* dense GEMMs. This is the MXU-shaped form: compiled
    FLOPs = active-expert FLOPs x capacity_factor (EXPERIMENTS.md §Perf,
    kimi-k2 iteration). Tokens beyond per-expert capacity are dropped
    (standard GShard/Switch semantics)."""
    m, d = tokens.shape
    if impl == "ragged":
        safe_eids = jnp.minimum(eids, n_experts - 1)  # trash rows are zero tokens
        order = jnp.argsort(safe_eids)
        sorted_tok = tokens[order]
        group_sizes = jnp.bincount(safe_eids, length=n_experts).astype(jnp.int32)
        gate = jax.lax.ragged_dot(sorted_tok, p["w_gate"], group_sizes)
        up = jax.lax.ragged_dot(sorted_tok, p["w_up"], group_sizes)
        h = (jax.nn.silu(gate.astype(jnp.float32)).astype(tokens.dtype)) * up.astype(tokens.dtype)
        out = jax.lax.ragged_dot(h, p["w_down"], group_sizes).astype(tokens.dtype)
        return jnp.zeros_like(out).at[order].set(out)  # unsort

    assert impl == "batched", impl
    cap = max(int(np.ceil(m / n_experts * capacity_factor)), 1)
    order = jnp.argsort(eids)
    eid_s = eids[order]
    counts = jnp.bincount(eid_s, length=n_experts + 1)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(m) - starts[eid_s]
    valid = (pos < cap) & (eid_s < n_experts)
    buf = jnp.zeros((n_experts + 1, cap, d), tokens.dtype).at[
        jnp.where(valid, eid_s, n_experts), pos
    ].set(tokens[order], mode="drop")
    buf = buf[:n_experts]
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    # (kimi §Perf iteration 3 tried bf16 GLU here — refuted: the dominant
    # converts are the attention chunk accumulators, not this path)
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(tokens.dtype) * up.astype(tokens.dtype)
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).astype(tokens.dtype)
    gathered = out[jnp.minimum(eid_s, n_experts - 1), jnp.minimum(pos, cap - 1)]
    gathered = jnp.where(valid[:, None], gathered, 0)
    return jnp.zeros_like(tokens).at[order].set(gathered)


# ---------------------------------------------------------------------------
# Local path
# ---------------------------------------------------------------------------


def moe_local(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Single-shard routed-experts forward. x: (b, s, d)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    ids, probs, aux = _route(xf, p["router"], m)
    n, k = ids.shape
    tok_idx = jnp.repeat(jnp.arange(n), k)
    flat_ids = ids.reshape(-1)
    out_flat = _expert_ffn(
        xf[tok_idx], flat_ids, p, m.n_experts,
        impl=getattr(m, "expert_impl", "ragged"),
        capacity_factor=m.capacity_factor + 0.25,
    )
    y = jnp.zeros_like(xf).at[tok_idx].add(out_flat * probs.reshape(-1)[:, None])
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map body)
# ---------------------------------------------------------------------------


def _moe_ep_body(p: dict, x: jax.Array, cfg, model_axis: str, data_axes: tuple[str, ...]):
    """Per-device body under shard_map. x: (b_loc, s, d); expert weights are
    the local expert slice (E_loc, ...)."""
    m = cfg.moe
    if hasattr(jax.lax, "axis_size"):
        n_shards = jax.lax.axis_size(model_axis)
    else:  # older jax: count the axis by reducing a 1 over it
        n_shards = jax.lax.psum(1, model_axis)
    e_loc = m.n_experts // n_shards
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    n = xf.shape[0]

    ids, probs, aux = _route(xf, p["router"], m)
    flat_ids = ids.reshape(-1)  # (n*k,)
    tok_idx = jnp.repeat(jnp.arange(n), m.top_k)
    owner = flat_ids // e_loc

    cap = int(np.ceil(n * m.top_k / n_shards * m.capacity_factor))
    # sort assignments by owner; position within owner group
    order = jnp.argsort(owner)
    owner_s = owner[order]
    counts = jnp.bincount(owner_s, length=n_shards)
    starts = jnp.concatenate([jnp.zeros(1, counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n * m.top_k) - starts[owner_s]
    valid = pos < cap
    # capacity-bounded scatter into per-owner send buffers (drop overflow)
    row = jnp.where(valid, owner_s, n_shards)  # out-of-range -> dropped
    send_tok = jnp.zeros((n_shards, cap, d), x.dtype).at[row, pos].set(
        xf[tok_idx[order]], mode="drop"
    )
    # unwritten (padding) slots carry the trash expert id e_loc so the
    # batched expert impl never charges them against a real expert's capacity
    send_eid = jnp.full((n_shards, cap), e_loc, jnp.int32).at[row, pos].set(
        (flat_ids[order] % e_loc).astype(jnp.int32), mode="drop"
    )

    # exchange: recv[j] = what peer j sent to me
    recv_tok = jax.lax.all_to_all(send_tok, model_axis, split_axis=0, concat_axis=0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis, split_axis=0, concat_axis=0, tiled=False)

    # local expert compute (dropped slots are zero tokens -> zero outputs)
    out = _expert_ffn(
        recv_tok.reshape(-1, d), recv_eid.reshape(-1), p, e_loc,
        impl=getattr(m, "expert_impl", "ragged"),
        capacity_factor=m.capacity_factor + 0.25,
    )
    out = out.reshape(n_shards, cap, d)

    # reverse exchange and weighted combine
    back = jax.lax.all_to_all(out, model_axis, split_axis=0, concat_axis=0, tiled=False)
    w = jnp.where(valid, probs.reshape(-1)[order], 0).astype(x.dtype)
    gathered = back[jnp.clip(row, 0, n_shards - 1), pos]  # (n*k, d)
    y = jnp.zeros_like(xf).at[tok_idx[order]].add(gathered * w[:, None])

    aux = jax.lax.pmean(aux, data_axes) if data_axes else aux
    return y.reshape(b, s, d), aux


def moe_ep(p: dict, x: jax.Array, cfg, mesh, data_axes: tuple[str, ...], model_axis: str):
    """shard_map-wrapped expert-parallel MoE."""
    from jax.sharding import PartitionSpec as P

    dp = tuple(data_axes)
    body = partial(_moe_ep_body, cfg=cfg, model_axis=model_axis, data_axes=dp)
    param_specs = {
        "router": P(None, None),
        "w_gate": P(model_axis, None, None),
        "w_up": P(model_axis, None, None),
        "w_down": P(model_axis, None, None),
    }
    pp = {k: p[k] for k in param_specs}
    if hasattr(jax, "shard_map"):  # jax >= 0.6
        smap = partial(jax.shard_map, check_vma=False)
    else:  # older jax: experimental home, check flag spelled check_rep
        from jax.experimental.shard_map import shard_map as _shard_map

        smap = partial(_shard_map, check_rep=False)
    return smap(
        body,
        mesh=mesh,
        in_specs=(param_specs, P(dp, None, None)),
        out_specs=(P(dp, None, None), P()),
    )(pp, x)


# ---------------------------------------------------------------------------
# Full MoE layer (shared + routed)
# ---------------------------------------------------------------------------


def apply_moe(
    p: dict, x: jax.Array, cfg, mesh=None, data_axes: tuple[str, ...] = (), model_axis: str = ""
) -> tuple[jax.Array, jax.Array]:
    m = cfg.moe
    use_ep = (
        mesh is not None
        and model_axis
        and mesh.shape[model_axis] > 1
        and m.n_experts % mesh.shape[model_axis] == 0
    )
    if use_ep:
        y, aux = moe_ep(p, x, cfg, mesh, data_axes, model_axis)
    else:
        y, aux = moe_local(p, x, cfg)
    if m.n_shared:
        sp = p["shared"]
        h = jax.nn.silu((x @ sp["gate"]).astype(jnp.float32)).astype(x.dtype) * (x @ sp["up"])
        y = y + h @ sp["down"]
    return y, aux
