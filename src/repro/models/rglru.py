"""Griffin/RecurrentGemma recurrent block: causal conv + RG-LRU.

Recurrence (Griffin, arXiv:2402.19427):

    r_t = sigmoid(W_r u_t + b_r)            # recurrence gate
    i_t = sigmoid(W_i u_t + b_i)            # input gate
    log a_t = -c * softplus(Lambda) * r_t   # c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Train/prefill uses ``jax.lax.associative_scan`` on the affine pairs
(a, b) — the same math the Pallas kernel (repro.kernels.rg_lru) computes
with a blocked sequential grid. Decode is a single-step state update.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import truncated_normal

_C = 8.0
_CONV_WIDTH = 4


class RGLRUState(NamedTuple):
    h: jax.Array  # (b, d_rnn) recurrent state
    conv: jax.Array  # (b, CONV_WIDTH-1, d_rnn) trailing conv inputs


def init_rglru(key, cfg, dtype) -> dict:
    d, dr = cfg.d_model, cfg.resolved_d_rnn
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    s = cfg.init_scale / np.sqrt(d)
    sr = cfg.init_scale / np.sqrt(dr)
    # Lambda init so that a spans ~[0.9, 0.999] (Griffin appendix)
    lam = jnp.asarray(
        np.log(np.expm1(-np.log(np.random.RandomState(0).uniform(0.9, 0.999, dr)) / _C)),
        jnp.float32,
    )
    return {
        "w_in": truncated_normal(k1, (d, dr), dtype, s),
        "w_gate": truncated_normal(k2, (d, dr), dtype, s),
        "w_out": truncated_normal(k3, (dr, d), dtype, sr),
        "conv_w": truncated_normal(k4, (_CONV_WIDTH, dr), dtype, 0.5),
        "w_r": truncated_normal(k5, (dr, dr), dtype, sr),
        "w_i": truncated_normal(k6, (dr, dr), dtype, sr),
        "b_r": jnp.zeros((dr,), dtype),
        "b_i": jnp.zeros((dr,), dtype),
        "lam": lam,
    }


def rglru_axes(cfg) -> dict:
    return {
        "w_in": ("embed", "rnn"),
        "w_gate": ("embed", "rnn"),
        "w_out": ("rnn", "embed"),
        "conv_w": (None, "rnn"),
        "w_r": ("rnn", "rnn_in"),
        "w_i": ("rnn", "rnn_in"),
        "b_r": ("rnn",),
        "b_i": ("rnn",),
        "lam": ("rnn",),
    }


def _gates(p: dict, u: jax.Array):
    """a (decay) and gated input b for the linear recurrence (fp32)."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["w_r"].astype(jnp.float32) + p["b_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ p["w_i"].astype(jnp.float32) + p["b_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def rglru_scan(p: dict, u: jax.Array, h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Full-sequence RG-LRU. u: (b, s, dr) -> (outputs, final_state)."""
    a, b = _gates(p, u)
    if h0 is not None:
        # fold the initial state into the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, b), axis=1)
    return hh.astype(u.dtype), hh[:, -1]


def rglru_step(p: dict, u_t: jax.Array, h: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single decode step. u_t: (b, dr), h: (b, dr) fp32."""
    a, b = _gates(p, u_t[:, None, :])
    h_new = a[:, 0] * h + b[:, 0]
    return h_new.astype(u_t.dtype), h_new


def _causal_conv(p: dict, u: jax.Array, tail: jax.Array | None = None):
    """Depthwise causal conv width 4. u: (b, s, dr)."""
    w = p["conv_w"]
    if tail is None:
        pad = jnp.zeros((u.shape[0], _CONV_WIDTH - 1, u.shape[2]), u.dtype)
    else:
        pad = tail.astype(u.dtype)
    ext = jnp.concatenate([pad, u], axis=1)  # (b, s+3, dr)
    s = u.shape[1]
    out = sum(ext[:, i : i + s] * w[_CONV_WIDTH - 1 - i] for i in range(_CONV_WIDTH))
    new_tail = ext[:, -(_CONV_WIDTH - 1) :]
    return out, new_tail


def apply_rglru_mix(
    p: dict,
    x: jax.Array,
    cfg,
    state: RGLRUState | None = None,
) -> tuple[jax.Array, RGLRUState | None]:
    """Temporal-mixing sub-layer (replaces attention). x: (b, s, d)."""
    u = x @ p["w_in"]
    g = x @ p["w_gate"]
    if state is None:
        u, _ = _causal_conv(p, u)
        h, _ = rglru_scan(p, u)
        new_state = None
    else:
        u, new_tail = _causal_conv(p, u, tail=state.conv)
        if x.shape[1] == 1:
            h, h_state = rglru_step(p, u[:, 0], state.h)
            h = h[:, None]
        else:
            h, h_state = rglru_scan(p, u, h0=state.h)
        new_state = RGLRUState(h_state, new_tail)
    y = (h * jax.nn.gelu(g.astype(jnp.float32)).astype(h.dtype)) @ p["w_out"]
    return y, new_state


def init_rglru_state(batch: int, cfg, dtype=jnp.float32) -> RGLRUState:
    dr = cfg.resolved_d_rnn
    return RGLRUState(
        h=jnp.zeros((batch, dr), jnp.float32),
        conv=jnp.zeros((batch, _CONV_WIDTH - 1, dr), dtype),
    )
