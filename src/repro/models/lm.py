"""TransformerLM: one scan-over-layers model covering all assigned families.

Layer layout = ``head`` (unrolled leading layers, e.g. MoE first-k-dense) +
``units`` (the repeating block pattern, parameters stacked over units and
scanned — compile time is O(1) in depth) + ``tail`` (unrolled remainder when
n_layers % len(pattern) != 0).

Block kinds: ``attn`` (attention + MLP/MoE), ``rglru`` (Griffin recurrent +
MLP), ``mlstm``/``slstm`` (xLSTM, self-contained). Frontends: ``audio``
(HuBERT-style precomputed frame embeddings replace token embedding) and
``vision`` (Qwen2-VL-style patch embeddings occupy the first
``n_frontend_tokens`` positions; M-RoPE).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import attention as A
from . import moe as MOE
from . import rglru as RG
from . import xlstm as XL
from .blocks import (
    apply_mlp,
    apply_norm,
    cross_entropy_loss,
    embed_axes,
    embed_tokens,
    init_embed,
    init_mlp,
    init_norm,
    lm_logits,
    mlp_axes,
    norm_axes,
    truncated_normal,
)


class MeshContext(NamedTuple):
    """Static distribution context threaded through the model."""

    mesh: Any = None
    data_axes: tuple[str, ...] = ()
    model_axis: str = ""
    seq_axis: str = ""  # set by the sequence-parallel plan

    def constrain_batch(self, x: jax.Array) -> jax.Array:
        """Anchor activation sharding: batch over the DP axes (+ optionally
        sequence over the model axis for the SP plan)."""
        if self.mesh is None or not self.data_axes:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P

        axes = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        if x.shape[0] % int(np.prod([self.mesh.shape[a] for a in self.data_axes])) != 0:
            return x
        rest = [None] * (x.ndim - 1)
        if (
            self.seq_axis
            and x.ndim >= 2
            and x.shape[1] % self.mesh.shape[self.seq_axis] == 0
        ):
            rest[0] = self.seq_axis
        spec = P(axes, *rest)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


# ---------------------------------------------------------------------------
# Per-block init / axes / apply
# ---------------------------------------------------------------------------


def _init_block(key, cfg, kind: str, moe_layer: bool, dtype) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "attn":
        p = {"norm1": init_norm(cfg, dtype), "attn": A.init_attention(k1, cfg, dtype),
             "norm2": init_norm(cfg, dtype)}
        if moe_layer:
            p["moe"] = MOE.init_moe(k2, cfg, dtype)
        else:
            d_ff = cfg.moe.d_ff_dense if cfg.moe is not None else cfg.d_ff
            p["mlp"] = init_mlp(k2, cfg, d_ff=d_ff, dtype=dtype)
        return p
    if kind == "rglru":
        return {
            "norm1": init_norm(cfg, dtype), "rec": RG.init_rglru(k1, cfg, dtype),
            "norm2": init_norm(cfg, dtype), "mlp": init_mlp(k2, cfg, dtype=dtype),
        }
    if kind == "mlstm":
        return {"norm1": init_norm(cfg, dtype), "mix": XL.init_mlstm(k1, cfg, dtype)}
    if kind == "slstm":
        return {"norm1": init_norm(cfg, dtype), "mix": XL.init_slstm(k1, cfg, dtype)}
    raise ValueError(kind)


def _block_axes(cfg, kind: str, moe_layer: bool) -> dict:
    if kind == "attn":
        p = {"norm1": norm_axes(cfg), "attn": A.attention_axes(cfg), "norm2": norm_axes(cfg)}
        if moe_layer:
            p["moe"] = MOE.moe_axes(cfg)
        else:
            p["mlp"] = mlp_axes(cfg)
        return p
    if kind == "rglru":
        return {"norm1": norm_axes(cfg), "rec": RG.rglru_axes(cfg),
                "norm2": norm_axes(cfg), "mlp": mlp_axes(cfg)}
    if kind == "mlstm":
        return {"norm1": norm_axes(cfg), "mix": XL.mlstm_axes(cfg)}
    if kind == "slstm":
        return {"norm1": norm_axes(cfg), "mix": XL.slstm_axes(cfg)}
    raise ValueError(kind)


def _apply_block(
    p: dict,
    x: jax.Array,
    cfg,
    kind: str,
    moe_layer: bool,
    mctx: MeshContext,
    *,
    positions: jax.Array,
    cache=None,
    cache_pos=0,
):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        h, new_cache = A.attend(
            p["attn"], apply_norm(p["norm1"], x, cfg.norm), cfg,
            positions=positions, cache=cache, cache_pos=cache_pos,
        )
        x = x + h
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if moe_layer:
            ff, aux = MOE.apply_moe(
                p["moe"], h2, cfg, mesh=mctx.mesh,
                data_axes=mctx.data_axes, model_axis=mctx.model_axis,
            )
        else:
            ff = apply_mlp(p["mlp"], h2, cfg)
        return x + ff, new_cache, aux
    if kind == "rglru":
        h, new_state = RG.apply_rglru_mix(p["rec"], apply_norm(p["norm1"], x, cfg.norm), cfg, state=cache)
        x = x + h
        x = x + apply_mlp(p["mlp"], apply_norm(p["norm2"], x, cfg.norm), cfg)
        return x, new_state, aux
    if kind == "mlstm":
        h, new_state = XL.mlstm_scan(p["mix"], apply_norm(p["norm1"], x, cfg.norm), cfg,
                                     state=cache if cache is not None else None)
        return x + h, new_state, aux
    if kind == "slstm":
        h, new_state = XL.slstm_scan(p["mix"], apply_norm(p["norm1"], x, cfg.norm), cfg,
                                     state=cache if cache is not None else None)
        return x + h, new_state, aux
    raise ValueError(kind)


def _init_block_cache(cfg, kind: str, batch: int, max_seq: int, dtype):
    if kind == "attn":
        return A.init_kv_cache(batch, max_seq, cfg, dtype)
    if kind == "rglru":
        return RG.init_rglru_state(batch, cfg, dtype)
    if kind == "mlstm":
        return XL.init_mlstm_state(batch, cfg)
    if kind == "slstm":
        return XL.init_slstm_state(batch, cfg)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class LM:
    def __init__(self, cfg, mctx: MeshContext | None = None, *, remat: bool = True,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.mctx = mctx or MeshContext()
        self.remat = remat
        self.dtype = dtype
        # layer layout
        pat = cfg.block_pattern
        if cfg.moe is not None:
            self.head_kinds = ["attn"] * cfg.moe.first_k_dense
            self.head_moe = [False] * cfg.moe.first_k_dense
            self.n_units = cfg.n_layers - cfg.moe.first_k_dense
            self.unit_pattern = ("attn",)
            self.unit_moe = (True,)
            self.tail_kinds: list[str] = []
            self.tail_moe: list[bool] = []
        else:
            self.head_kinds, self.head_moe = [], []
            self.n_units, rem = divmod(cfg.n_layers, len(pat))
            self.unit_pattern = pat
            self.unit_moe = tuple(False for _ in pat)
            self.tail_kinds = list(pat[:rem])
            self.tail_moe = [False] * rem

    # -- init ---------------------------------------------------------------
    def init(self, key) -> dict:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: dict = {"embed": init_embed(keys[0], cfg, dtype)}
        if cfg.frontend:
            params["frontend"] = {
                "proj": truncated_normal(
                    keys[1], (cfg.frontend_dim, cfg.d_model), dtype,
                    cfg.init_scale / np.sqrt(cfg.frontend_dim),
                )
            }
        params["head"] = [
            _init_block(k, cfg, kind, moe, dtype)
            for k, kind, moe in zip(
                jax.random.split(keys[2], max(len(self.head_kinds), 1)),
                self.head_kinds, self.head_moe,
            )
        ]
        unit_params = []
        for i, (kind, moe) in enumerate(zip(self.unit_pattern, self.unit_moe)):
            ks = jax.random.split(jax.random.fold_in(keys[3], i), self.n_units)
            unit_params.append(
                jax.vmap(lambda k: _init_block(k, cfg, kind, moe, dtype))(ks)
            )
        params["units"] = unit_params
        params["tail"] = [
            _init_block(k, cfg, kind, moe, dtype)
            for k, kind, moe in zip(
                jax.random.split(keys[4], max(len(self.tail_kinds), 1)),
                self.tail_kinds, self.tail_moe,
            )
        ]
        params["final_norm"] = init_norm(cfg, dtype)
        return params

    def param_axes(self) -> dict:
        """Logical-axis annotations, same tree structure as init()."""
        cfg = self.cfg
        axes: dict = {"embed": embed_axes(cfg)}
        if cfg.frontend:
            axes["frontend"] = {"proj": ("frontend", "embed")}
        axes["head"] = [_block_axes(cfg, k, m) for k, m in zip(self.head_kinds, self.head_moe)]
        axes["units"] = [
            jax.tree.map(lambda a: (None,) + a if isinstance(a, tuple) else a,
                         _block_axes(cfg, k, m), is_leaf=lambda a: isinstance(a, tuple))
            for k, m in zip(self.unit_pattern, self.unit_moe)
        ]
        axes["tail"] = [_block_axes(cfg, k, m) for k, m in zip(self.tail_kinds, self.tail_moe)]
        axes["final_norm"] = norm_axes(cfg)
        return axes

    # -- embedding / frontend -------------------------------------------------
    def _embed(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        if cfg.frontend == "audio":
            return batch["frames"].astype(self.dtype) @ params["frontend"]["proj"]
        x = embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.frontend == "vision" and "patches" in batch:
            pe = batch["patches"].astype(x.dtype) @ params["frontend"]["proj"]
            n = pe.shape[1]
            x = jnp.concatenate([pe, x[:, n:]], axis=1)
        return x

    # -- full-sequence forward ------------------------------------------------
    def forward(self, params: dict, batch: dict) -> tuple[jax.Array, jax.Array]:
        """Returns (logits, moe_aux)."""
        cfg, mctx = self.cfg, self.mctx
        x = mctx.constrain_batch(self._embed(params, batch))
        b, s = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        aux_total = jnp.zeros((), jnp.float32)

        for p, kind, moe in zip(params["head"], self.head_kinds, self.head_moe):
            x, _, aux = _apply_block(p, x, cfg, kind, moe, mctx, positions=positions)
            aux_total += aux

        if self.n_units:
            def unit_fn(carry, unit_p):
                x = carry
                aux_sum = jnp.zeros((), jnp.float32)
                for p, kind, moe in zip(unit_p, self.unit_pattern, self.unit_moe):
                    x, _, aux = _apply_block(p, x, cfg, kind, moe, mctx, positions=positions)
                    aux_sum += aux
                return x, aux_sum

            body = jax.checkpoint(unit_fn) if self.remat else unit_fn
            x, auxs = jax.lax.scan(body, x, tuple(params["units"]))
            aux_total += auxs.sum()

        for p, kind, moe in zip(params["tail"], self.tail_kinds, self.tail_moe):
            x, _, aux = _apply_block(p, x, cfg, kind, moe, mctx, positions=positions)
            aux_total += aux

        x = apply_norm(params["final_norm"], x, cfg.norm)
        return lm_logits(params["embed"], x, cfg), aux_total

    def loss(self, params: dict, batch: dict) -> jax.Array:
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.causal:
            targets = batch["tokens"][:, 1:]
            logits = logits[:, :-1]
            mask = jnp.ones_like(targets)
        else:  # encoder-only: per-position classification (HuBERT targets)
            targets = batch["labels"]
            mask = jnp.ones_like(targets)
        ce = cross_entropy_loss(logits, targets, mask)
        w = cfg.moe.router_aux_weight if cfg.moe is not None else 0.0
        return ce + w * aux

    # -- decode ---------------------------------------------------------------
    def init_decode_state(self, batch: int, max_seq: int, cache_dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        state: dict = {
            "head": [
                _init_block_cache(cfg, k, batch, max_seq, cache_dtype) for k in self.head_kinds
            ],
            "tail": [
                _init_block_cache(cfg, k, batch, max_seq, cache_dtype) for k in self.tail_kinds
            ],
        }
        units = []
        for kind in self.unit_pattern:
            one = _init_block_cache(cfg, kind, batch, max_seq, cache_dtype)
            units.append(jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (self.n_units,) + a.shape), one
            ))
        state["units"] = units
        return state

    def decode_state_axes(self) -> dict:
        """Logical-axis annotations matching init_decode_state structure."""

        def block_axes(kind: str):
            if kind == "attn":
                kv = ("batch", "seq", "kv_heads", "head_dim")
                return A.KVCache(kv, kv)
            if kind == "rglru":
                return RG.RGLRUState(h=("batch", "rnn"), conv=("batch", None, "rnn"))
            if kind == "mlstm":
                return XL.MLSTMState(
                    c=("batch", "heads", None, "rnn"),
                    n=("batch", "heads", "rnn"),
                    m=("batch", "heads"),
                )
            if kind == "slstm":
                return XL.SLSTMState(
                    c=("batch", "rnn"), n=("batch", "rnn"),
                    m=("batch", "rnn"), h=("batch", "rnn"),
                )
            raise ValueError(kind)

        def stack(axes_tree):
            return jax.tree.map(
                lambda a: (None,) + a,
                axes_tree,
                is_leaf=lambda a: isinstance(a, tuple) and all(
                    isinstance(x, (str, type(None))) for x in a
                ),
            )

        return {
            "head": [block_axes(k) for k in self.head_kinds],
            "units": [stack(block_axes(k)) for k in self.unit_pattern],
            "tail": [block_axes(k) for k in self.tail_kinds],
        }

    def decode_step(
        self, params: dict, tokens: jax.Array, state: dict, pos: jax.Array
    ) -> tuple[jax.Array, dict]:
        """One decode step. tokens: (b, 1) (or (b, n) block); pos: scalar
        current cache length. Returns (logits for last position, new state)."""
        cfg, mctx = self.cfg, self.mctx
        x = embed_tokens(params["embed"], tokens, cfg)
        if tokens.shape[1] > 1:  # block prefill: same anchoring as forward
            x = mctx.constrain_batch(x)
        b, s = x.shape[0], x.shape[1]
        positions = pos + jnp.broadcast_to(jnp.arange(s), (b, s))
        new_state: dict = {"head": [], "tail": [], "units": []}

        for p, kind, moe, c in zip(params["head"], self.head_kinds, self.head_moe, state["head"]):
            x, nc, _ = _apply_block(p, x, cfg, kind, moe, mctx,
                                    positions=positions, cache=c, cache_pos=pos)
            new_state["head"].append(nc)

        if self.n_units:
            def unit_fn(carry, scanned):
                x = carry
                unit_p, unit_c = scanned
                ncs = []
                for p, kind, moe, c in zip(unit_p, self.unit_pattern, self.unit_moe, unit_c):
                    x, nc, _ = _apply_block(p, x, cfg, kind, moe, mctx,
                                            positions=positions, cache=c, cache_pos=pos)
                    ncs.append(nc)
                return x, tuple(ncs)

            x, new_unit_state = jax.lax.scan(
                unit_fn, x, (tuple(params["units"]), tuple(state["units"]))
            )
            new_state["units"] = list(new_unit_state)

        for p, kind, moe, c in zip(params["tail"], self.tail_kinds, self.tail_moe, state["tail"]):
            x, nc, _ = _apply_block(p, x, cfg, kind, moe, mctx,
                                    positions=positions, cache=c, cache_pos=pos)
            new_state["tail"].append(nc)

        x = apply_norm(params["final_norm"], x, cfg.norm)
        logits = lm_logits(params["embed"], x[:, -1:], cfg)
        return logits, new_state
